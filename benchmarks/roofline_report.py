"""Roofline reporting: dry-run aggregate table + live ES-RNN entry points.

Two sections:

* :func:`main` -- aggregate previously saved dry-run JSONs into the
  EXPERIMENTS.md roofline table (unchanged from the seed).
* :func:`esrnn_section` -- compile the *real* ES-RNN programs (the donated
  fused train superstep from ``repro.train.engine`` and the forecast
  program, sharded over a series mesh when this process has multiple
  devices) at both precision policies and report FLOPs, HBM bytes,
  arithmetic intensity and the roofline time terms per entry point. This
  is the ``roofline`` column of the BENCH_PR10 trajectory; CI gates the
  bf16/fp32 fused-step byte ratio.
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def esrnn_section(fast: bool = False) -> dict:
    """fp32-vs-bf16 roofline of the live fit/predict programs.

    Returns the :func:`repro.roofline.esrnn.precision_compare` payload with
    a ``sharded_predict`` flag recording whether the predict rows went
    through the series-mesh ``shard_map`` program.
    """
    import jax

    from repro.core.esrnn import make_config
    from repro.roofline.esrnn import precision_compare

    mesh = None
    if len(jax.devices()) > 1:
        from repro.sharding.series import make_series_mesh

        mesh = make_series_mesh()
    out = precision_compare(make_config("quarterly"), mesh=mesh)
    out["sharded_predict"] = mesh is not None
    out["devices"] = len(jax.devices())
    return out


def print_esrnn_section(out: dict) -> None:
    print(f"  probe {out['probe']}  devices={out['devices']} "
          f"sharded_predict={out['sharded_predict']}")
    print("  entry    prec  flops/step   hlo_B/step  jaxpr_B/step  "
          "intensity  dominant")
    for r in out["rows"]:
        print(f"  {r['entry']:8s} {r['precision']:5s} {r['flops']:.3e}  "
              f"{r['hlo_bytes']:.3e}  {r['jaxpr_bytes']:.3e}   "
              f"{r['intensity']:8.2f}  {r['dominant']}")
    print(f"  fit bf16/fp32 bytes: jaxpr "
          f"{out['fit_jaxpr_bytes_ratio_bf16']:.3f} "
          f"(hardware-neutral, CI gate <= 0.65), hlo "
          f"{out['fit_hlo_bytes_ratio_bf16']:.3f} (this backend); "
          f"predict jaxpr {out['predict_jaxpr_bytes_ratio_bf16']:.3f}")


def load(mesh: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def fmt_row(r):
    if r.get("status") != "ok":
        return f"| {r['arch']} | {r['shape']} | FAILED: {r.get('error','')[:60]} |"
    t = r["roofline"]
    dom = t["dominant"]
    total = max(t["compute_s"], t["memory_s"], t["collective_s"])
    frac = t["compute_s"] / total if total else 0.0
    ratio = r.get("useful_flops_ratio")
    mem_gb = (r.get("memory_analysis", {}).get("argument_size", 0)
              + r.get("memory_analysis", {}).get("temp_size", 0)) / 2**30
    return (f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {dom} | "
            f"{frac:.3f} | {ratio:.2f} | {mem_gb:.1f} |" if ratio else
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {dom} | "
            f"{frac:.3f} | - | {mem_gb:.1f} |")


def markdown_table(mesh: str) -> str:
    rows = load(mesh)
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | roofline frac | 6ND/HLO | GB/chip |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(fmt_row(r) for r in rows)


def main():
    for mesh in ("single", "multi"):
        rows = load(mesh)
        if not rows:
            print(f"[{mesh}] no dry-run results yet "
                  f"(run: python -m repro.launch.dryrun --all --mesh {mesh})")
            continue
        ok = sum(1 for r in rows if r.get("status") == "ok")
        print(f"\n== {mesh} mesh: {ok}/{len(rows)} cells compiled ==")
        print(markdown_table(mesh))


if __name__ == "__main__":
    main()
