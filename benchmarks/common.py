"""Shared benchmark helpers."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses as L
from repro.core.esrnn import esrnn_forecast, make_config
from repro.data.pipeline import prepare
from repro.data.synthetic_m4 import generate
from repro.train.trainer import TrainConfig, train_esrnn

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


def train_frequency(freq: str, *, scale: float, steps: int, seed: int = 0,
                    lr: float = 4e-3, batch_size: int = 64):
    """Train an ES-RNN for one frequency on synthetic M4; returns
    (cfg, data, params, history)."""
    data = prepare(generate(freq, scale=scale, seed=seed))
    cfg = make_config(freq)
    out = train_esrnn(cfg, data, TrainConfig(
        batch_size=min(batch_size, data.n_series), n_steps=steps, lr=lr,
        eval_every=max(steps // 3, 1), ckpt_dir=None, seed=seed))
    return cfg, data, out["params"], out["history"]


def eval_test_smape(cfg, data, params):
    """Test-set sMAPE: forecast from train+val, score vs test (Eq. 7)."""
    fc = esrnn_forecast(cfg, params, jnp.asarray(data.val_input),
                        jnp.asarray(data.cats))
    return float(L.smape(fc, jnp.asarray(data.test_target))), np.asarray(fc)


def timeit(fn, *args, repeats: int = 3):
    fn(*args)  # warm / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") or isinstance(r, jax.Array) else None
    return (time.perf_counter() - t0) / repeats
